"""End-to-end SSL pipeline — the paper's recipe, laptop-scaled.

Stages (paper sections in brackets):
  baseline : student-architecture LSTM AM, CE on labeled data [§2]
  teacher  : bidirectional LSTM AM, CE (+ sMBR) on labeled data [§3.2]
  targets  : teacher inference over the unlabeled firehose -> top-k=20
             logits into the manifest-backed LogitStore v2, partitioned
             across gen_workers ledgered shard ranges [§3.2.2];
             resumable (work ledger) and wave-versioned (re-runs
             supersede atomically)
  student  : scheduled learning over unlabeled sub-epochs with labeled
             interleaves [§3.3], GTC or BMUF trainer [§3.5]
  smbr     : sequence training on labeled data only [§3.4], under
             threshold-compressed SGD

Every training stage is one ``Trainer.fit()`` call (repro.train): the
stage picks a DistributedStrategy (Local / BMUFVmap / GTC), a dict of
loss fns, and a DataSource; the Trainer owns the jit (one executable
per loss kind x batch shape, lr traced), periodic TrainState
checkpoints under <out>/ckpt_<stage>/state (killed stages resume
mid-stream; completed stages retire their resume state), and the
metrics sink.  Batches reach the jitted update through the async
prefetching feed (repro.pipeline.PrefetchingSource, depth
PipelineConfig.prefetch) so host-side shard decode overlaps device
compute.  Final params land in <out>/ckpt_<stage> — the cross-stage
interface.

Metrics include the frame-error-rate (FER) on a held-out synthetic VAL
set and the relative FER reduction vs the baseline — the
container-scale proxy for the paper's relative WERR (the paper only
ever reports relative numbers).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs.lstm_am_7khr import CONFIG as AM_CONFIG
from repro.configs.base import LayerSpec, Segment
from repro.core import scheduled
from repro.core.teacher import TeacherRunner
from repro.pipeline import generate_sharded
from repro.store import LogitStoreV2
from repro.data import FeatureConfig, SynthConfig
from repro.data.loader import CorpusLoader
from repro.distributed.bmuf import BMUFConfig
from repro.distributed.gtc import GTCConfig
from repro.launch.steps import make_loss_fn
from repro.models import build_model
from repro.runtime.cluster import worker_mesh
from repro.seqtrain import build_denominator_graph, make_smbr_loss_fn
from repro.seqtrain.smbr import frame_error_rate
from repro.train import (GTC, BMUFVmap, GTCShardMap, ListSink, Local,
                         TrainBatch, Trainer, chain, distill_shard_source,
                         epoch_source, scheduled_source)


def am_configs(*, n_layers: int, lstm_hidden: int, n_senones: int,
               feat_dim: int):
    """(student, teacher) ModelConfigs from the pipeline's scale knobs.

    Module-level (not a method) because the multi-process generation
    workers rebuild the teacher config from these same scalars on the
    far side of a process boundary (:func:`pipeline_teacher_engine`).
    """
    base = AM_CONFIG.replace(
        segments=(Segment((LayerSpec(mixer="lstm", ffn="none"),),
                          repeat=n_layers),),
        lstm_hidden=lstm_hidden, n_senones=n_senones,
        vocab_size=n_senones, feat_dim=feat_dim)
    teacher = base.replace(
        name="teacher",
        segments=(Segment((LayerSpec(mixer="bilstm", ffn="none"),),
                          repeat=n_layers),))
    return base, teacher


def _engine_from_ckpt(cfg, ckpt_dir: str, topk: int) -> TeacherRunner:
    model = build_model(cfg)
    like = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    like = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), like)
    params, _ = CheckpointStore(ckpt_dir).load(like)
    return TeacherRunner(cfg, params, k=topk)


def pipeline_teacher_engine(worker_id: int, kwargs: dict):
    """Engine factory spec ``repro.core.ssl_pipeline:
    pipeline_teacher_engine`` — a generation worker process rebuilds
    the pipeline's TeacherRunner from the teacher checkpoint on disk
    (kwargs: ckpt_dir + the :func:`am_configs` scalars + topk)."""
    del worker_id
    _, teacher_cfg = am_configs(
        n_layers=int(kwargs["n_layers"]),
        lstm_hidden=int(kwargs["lstm_hidden"]),
        n_senones=int(kwargs["n_senones"]),
        feat_dim=int(kwargs["feat_dim"]))
    return _engine_from_ckpt(teacher_cfg, kwargs["ckpt_dir"],
                             int(kwargs["topk"]))


def pipeline_student_engine(worker_id: int, kwargs: dict):
    """Engine factory spec ``repro.core.ssl_pipeline:
    pipeline_student_engine`` — the *promoted student* as the
    generation engine (iterative distillation: after a wave of
    scheduled learning the student regenerates the targets for the
    next wave).  Same kwargs as the teacher factory; the rebuilt config
    is the student (unidirectional) architecture."""
    del worker_id
    student_cfg, _ = am_configs(
        n_layers=int(kwargs["n_layers"]),
        lstm_hidden=int(kwargs["lstm_hidden"]),
        n_senones=int(kwargs["n_senones"]),
        feat_dim=int(kwargs["feat_dim"]))
    return _engine_from_ckpt(student_cfg, kwargs["ckpt_dir"],
                             int(kwargs["topk"]))


def _pad_time(batch: dict, t: int) -> dict:
    """Zero-pad every (B, T, ...) leaf of a full-seq batch to T = t
    (mask rows stay 0 over the padding, so losses are unchanged)."""
    out = {}
    for k, v in batch.items():
        if getattr(v, "ndim", 0) >= 2 and v.shape[1] < t:
            pad = [(0, 0)] * v.ndim
            pad[1] = (0, t - v.shape[1])
            out[k] = np.pad(v, pad)
        else:
            out[k] = v
    return out


@dataclass
class PipelineConfig:
    # data
    n_labeled: int = 48
    n_unlabeled: int = 192
    n_val: int = 16
    n_speakers: int = 16
    n_senones: int = 49
    mean_utt_sec: float = 1.2
    n_mels: int = 16
    # model
    n_layers: int = 2
    lstm_hidden: int = 64
    # training
    batch: int = 8
    chunk_len: int = 32
    epochs_baseline: int = 5
    lr: float = 5e-2
    topk: int = 10
    ckpt_every: int = 20              # TrainState resume-ckpt cadence
    # data plane
    gen_workers: int = 2              # target-generation workers (ledgered
                                      # disjoint shard ranges, engine each)
    gen_procs: int = 0                # >0: generation as N real OS
                                      # processes racing the shared ledger
                                      # (runtime.workers; manifest bitwise-
                                      # identical to in-process)
    prefetch: int = 2                 # async feed depth for Trainer.fit
                                      # (0 = synchronous)
    # schedule (paper-structured, scaled)
    n_sub_epochs: int = 4
    labeled_every: int = 2
    chunked_until: int = 3
    # trainers
    gtc_tau: float = 2e-4
    gtc_workers: int = 2              # sMBR sequence-training workers:
                                      # >1 runs GTCShardMap (int8 wire,
                                      # worker axis on a mesh), 1 the
                                      # single-process GTC strategy
    bmuf_workers: int = 4
    bmuf_block_steps: int = 2
    smbr_epochs: int = 2
    smbr_kappa: float = 0.3
    smbr_lr: float = 5e-3
    seed: int = 0

    @classmethod
    def tiny(cls) -> "PipelineConfig":
        return cls()

    @classmethod
    def small(cls) -> "PipelineConfig":
        return cls(n_labeled=128, n_unlabeled=640, n_val=32, n_speakers=32,
                   n_senones=97, lstm_hidden=128, n_layers=3,
                   epochs_baseline=4, n_sub_epochs=6, labeled_every=2,
                   chunked_until=4)

    @property
    def feat_dim(self) -> int:
        return self.n_mels * 3


class SSLPipeline:
    def __init__(self, pc: PipelineConfig, *, out_dir: str = "experiments/train",
                 student_trainer: str = "gtc"):
        self.pc = pc
        self.out = out_dir
        self.student_trainer = student_trainer
        os.makedirs(out_dir, exist_ok=True)

        self.synth = SynthConfig(n_speakers=pc.n_speakers,
                                 n_senones=pc.n_senones,
                                 mean_utt_sec=pc.mean_utt_sec, seed=pc.seed)
        self.feat = FeatureConfig(n_mels=pc.n_mels)
        # look-ahead 0 at laptop scale: the label-shift mechanism itself is
        # exercised by tests/test_data.py; a 30-90ms output delay is not
        # learnable by a 2x64 LSTM on minutes of audio (the paper's value
        # of 3 is one config knob away)
        self.loader = CorpusLoader(synth=self.synth, feat=self.feat,
                                   lookahead=0)
        self.loader.estimate_mvn(min(24, pc.n_labeled))

        self.student_cfg, self.teacher_cfg = am_configs(
            n_layers=pc.n_layers, lstm_hidden=pc.lstm_hidden,
            n_senones=pc.n_senones, feat_dim=pc.feat_dim)

        # utterance-id ranges: labeled / unlabeled / val are disjoint
        self.rng_labeled = (0, pc.n_labeled)
        self.rng_unlabeled = (10_000, pc.n_unlabeled)
        self.rng_val = (100_000, pc.n_val)
        self._val_batch = None

    # ------------------------------------------------------------- helpers

    def _batches(self, rng, *, chunked: bool, offset: int = 0, seed: int = 0,
                 uniform_len: bool = False):
        start, count = rng
        if chunked:
            return list(self.loader.chunked_batches(
                start, count, batch_size=self.pc.batch,
                chunk_len=self.pc.chunk_len, offset=offset, seed=seed))
        bs = list(self.loader.full_seq_batches(
            start, count, batch_size=max(2, self.pc.batch // 2),
            offset=offset))
        if uniform_len and bs:
            # pad every batch to the corpus max: multi-microbatch
            # strategies (GTCShardMap consumes one batch per worker)
            # group shape-mates, so ragged full-seq batches would drop
            # partial groups at every length boundary
            t = max(b["feats"].shape[1] for b in bs)
            bs = [_pad_time(b, t) for b in bs]
        return bs

    def val_batch(self):
        if self._val_batch is None:
            bs = self._batches(self.rng_val, chunked=False)
            self._val_batch = {k: jnp.asarray(v) for k, v in bs[0].items()}
        return self._val_batch

    def fer(self, cfg, params) -> float:
        model = build_model(cfg)
        vb = self.val_batch()
        h, _ = model.apply(params, vb["feats"])
        logits = model.unembed(params, h)
        return float(frame_error_rate(logits, vb["labels"], vb["mask"]))

    def _ckpt(self, stage) -> CheckpointStore:
        return CheckpointStore(os.path.join(self.out, f"ckpt_{stage}"))

    def _trainer(self, stage, strategy, loss_fns, sink) -> Trainer:
        """One Trainer per stage: resume state under ckpt_<stage>/state."""
        store = CheckpointStore(
            os.path.join(self.out, f"ckpt_{stage}", "state"))
        return Trainer(strategy, loss_fns, checkpoint=store,
                       ckpt_every=self.pc.ckpt_every, metrics=sink,
                       prefetch=self.pc.prefetch)

    def _load_or_none(self, stage, cfg):
        store = self._ckpt(stage)
        model = build_model(cfg)
        like = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        like = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), like)
        try:
            params, _ = store.load(like)
            return params
        except FileNotFoundError:
            return None

    def _ce_source(self, *, n_epochs, lr, seed0=0):
        """The supervised recipe: chunked-BPTT epochs with rotating
        feature offsets, then one full-sequence fine-tune epoch."""
        return chain(
            epoch_source(
                lambda ep: self._batches(self.rng_labeled, chunked=True,
                                         offset=ep % 3, seed=seed0 + ep),
                n_epochs, lr, "ce"),
            epoch_source(
                lambda ep: self._batches(self.rng_labeled, chunked=False),
                1, lr * 0.3, "ce"))

    # -------------------------------------------------------------- stages

    def stage_baseline(self) -> Dict:
        pc = self.pc
        model = build_model(self.student_cfg)
        sink = ListSink()
        tr = self._trainer("baseline", Local(),
                           {"ce": make_loss_fn(model, self.student_cfg,
                                               "ce")}, sink)
        state = tr.init_state(model.init(jax.random.key(pc.seed)),
                              seed=pc.seed)
        state = tr.fit(state, self._ce_source(n_epochs=pc.epochs_baseline,
                                              lr=pc.lr))
        tr.finalize(state)
        self._ckpt("baseline").save(0, state.params)
        # sink only saw post-resume updates: first/last may be None on a
        # run resumed at (or past) its final periodic checkpoint
        return {"loss_first": sink.first("loss"),
                "loss_last": sink.last("loss"),
                "val_fer": self.fer(self.student_cfg, state.params)}

    def stage_teacher(self) -> Dict:
        pc = self.pc
        model = build_model(self.teacher_cfg)
        sink = ListSink()
        tr = self._trainer("teacher", Local(),
                           {"ce": make_loss_fn(model, self.teacher_cfg,
                                               "ce")}, sink)
        state = tr.init_state(model.init(jax.random.key(pc.seed + 1)),
                              seed=pc.seed + 1)
        state = tr.fit(state, self._ce_source(n_epochs=pc.epochs_baseline,
                                              lr=pc.lr, seed0=100))

        # sMBR fine-tune of the teacher (paper's "with sMBR teacher" arm);
        # no grad clip — sMBR grads are already bounded by the posteriors
        smbr_sink = ListSink()
        smbr_tr = self._trainer(
            "teacher_smbr", Local(clip=0.0),
            {"smbr": make_smbr_loss_fn(model, self.teacher_cfg,
                                       self._graph(),
                                       kappa=pc.smbr_kappa)}, smbr_sink)
        sstate = smbr_tr.init_state(state.params, seed=pc.seed + 1)
        sstate = smbr_tr.fit(sstate, epoch_source(
            lambda ep: self._batches(self.rng_labeled, chunked=False),
            1, pc.smbr_lr, "smbr"))
        # retire resume state only once the whole stage is done — a kill
        # during the sMBR sub-fit must still resume (not retrain) the CE
        # part on re-invocation
        tr.finalize(state)
        smbr_tr.finalize(sstate)
        self._ckpt("teacher").save(0, sstate.params)
        return {"loss_last": sink.last("loss"),
                "val_fer": self.fer(self.teacher_cfg, sstate.params),
                "smbr_eacc": smbr_sink.last("expected_frame_acc")}

    def _graph(self):
        pairs = self.loader.featurized(*self.rng_labeled)
        return build_denominator_graph([l for _, l, _ in pairs],
                                       self.pc.n_senones)

    def stage_targets(self, *, promoted_stage: str = None) -> Dict:
        """Sharded generation through the data plane: the unlabeled
        corpus is partitioned across ``gen_workers`` ledgered shard
        ranges, one TeacherRunner (engine) per worker, into the
        manifest-backed LogitStore v2 — a killed run re-claims its
        unfinished ranges, a completed re-run supersedes the previous
        wave atomically.  ``promoted_stage`` switches the engine from
        the bidirectional teacher to that stage's *student* checkpoint
        (iterative distillation: the wave driver promotes the student
        to teacher between waves)."""
        pc = self.pc
        if promoted_stage is None:
            gen_cfg, ckpt_name = self.teacher_cfg, "teacher"
            factory = "pipeline_teacher_engine"
        else:
            gen_cfg, ckpt_name = self.student_cfg, promoted_stage
            factory = "pipeline_student_engine"
        gparams = self._load_or_none(ckpt_name, gen_cfg)
        assert gparams is not None, f"run stage {ckpt_name} first"
        store = LogitStoreV2(os.path.join(self.out, "logit_store"),
                             k=pc.topk, vocab=pc.n_senones)
        # host (numpy) batches: the jitted forward converts one batch at
        # a time, so device memory stays O(1 batch) over the whole corpus
        batches = [{"feats": b["feats"], "mask": b["mask"]}
                   for b in self._batches(self.rng_unlabeled, chunked=True,
                                          seed=7)]

        if pc.gen_procs >= 1:
            # real OS processes: each rebuilds the engine from the
            # checkpoint (the factory spec crosses the process boundary;
            # params cannot) — manifest bitwise-identical to in-process
            make_engine = f"repro.core.ssl_pipeline:{factory}"
            engine_kwargs = {
                "ckpt_dir": os.path.join(self.out, f"ckpt_{ckpt_name}"),
                "n_layers": pc.n_layers, "lstm_hidden": pc.lstm_hidden,
                "n_senones": pc.n_senones, "feat_dim": pc.feat_dim,
                "topk": pc.topk}
        else:
            engine_kwargs = None

            def make_engine(worker: int):
                return TeacherRunner(gen_cfg, gparams, k=pc.topk)

        report = generate_sharded(
            make_engine, batches, store, n_workers=pc.gen_workers,
            ledger_path=os.path.join(self.out, "gen_ledger.json"),
            processes=pc.gen_procs, engine_kwargs=engine_kwargs)
        store.verify()                    # manifest-checksum every shard
        meta = store.stats()
        full = meta.n_frames * pc.n_senones * 4
        packed = meta.n_frames * (pc.topk * 6)
        out = {"n_shards": report["n_shards"], "n_frames": meta.n_frames,
               "n_workers": report["n_workers"], "wave": report["wave"],
               "resumed": report["resumed"],
               "storage_compression_x": round(full / packed, 1)}
        if pc.gen_procs >= 1:             # the fleet's completion report
            out.update({k: report[k] for k in ("processes", "restarts",
                                               "reclaimed")})
            # structured steal/lifecycle events from the supervisor +
            # ledger: who stole what from whom, by which signal, how old
            events = report.get("events", [])
            out["n_steals"] = sum(e.get("event") == "steal"
                                  for e in events)
            out["events"] = events[-20:]
        return out

    def _student_strategy(self):
        pc = self.pc
        if self.student_trainer == "bmuf":
            return BMUFVmap(BMUFConfig(n_workers=pc.bmuf_workers,
                                       block_steps=pc.bmuf_block_steps))
        return GTC(GTCConfig(tau=pc.gtc_tau, n_workers=1))

    def stage_student(self, *, membership=None, init_params=None,
                      stage: str = None) -> Dict:
        """Scheduled learning on unlabeled top-k targets + labeled
        passes — same loop for both trainers; only the strategy differs.
        ``membership`` (anything with ``live_count()``) makes the fit
        elastic: worker deaths shrink the BMUF block at the next block
        boundary, revivals grow it back.  ``init_params``/``stage``
        let the wave driver chain waves (each wave trains from the
        previous wave's promoted params under its own checkpoint
        stage)."""
        pc = self.pc
        baseline = (init_params if init_params is not None
                    else self._load_or_none("baseline", self.student_cfg))
        assert baseline is not None, "run stage baseline first"
        # the workers=1 consumer of whatever N workers generated: the
        # manifest is the contract — verify() checksums every live shard
        store = LogitStoreV2(os.path.join(self.out, "logit_store"),
                             k=pc.topk, vocab=pc.n_senones)
        unl_batches = self._batches(self.rng_unlabeled, chunked=True, seed=7)
        assert len(store.shards()) == len(unl_batches), "regenerate targets"
        store.verify()
        per_sub = max(1, len(unl_batches) // pc.n_sub_epochs)
        sched = scheduled.ScheduleConfig(
            n_sub_epochs=pc.n_sub_epochs, sub_epoch_hours=1.0,
            labeled_every=pc.labeled_every, chunked_until=pc.chunked_until,
            lr0=pc.lr, labeled_lr_boost=1.5)

        stage = stage or f"student_{self.student_trainer}"
        model = build_model(self.student_cfg)
        sink = ListSink()
        tr = self._trainer(
            stage, self._student_strategy(),
            {"distill_topk": make_loss_fn(model, self.student_cfg,
                                          "distill_topk"),
             "ce": make_loss_fn(model, self.student_cfg, "ce")}, sink)
        state = tr.init_state(baseline, seed=pc.seed)

        def unlabeled(phase):
            lo = (phase.sub_epoch - 1) * per_sub
            # pin_wave: each sub-epoch snapshots its shards' manifest
            # entries at start — a teacher regeneration landing a new
            # wave mid-sub-epoch cannot mix targets into this pass
            return distill_shard_source(unl_batches, store, lo,
                                        lo + per_sub, phase.lr,
                                        pin_wave=True)

        def labeled(phase):
            return (TrainBatch(b, phase.lr, "ce")
                    for b in self._batches(
                        self.rng_labeled, chunked=phase.chunked,
                        offset=max(phase.feature_offset, 0)))

        state = tr.fit(state, scheduled_source(sched, unlabeled=unlabeled,
                                               labeled=labeled),
                       membership=membership)
        tr.finalize(state)
        self._ckpt(stage).save(0, state.params)
        out = self._student_metrics(state.params, sink.values("loss"))
        if membership is not None:
            out["resizes"] = dict(tr.resize_stats)
            out["final_workers"] = getattr(tr.strategy, "n_workers", 1)
        return out

    def _student_metrics(self, params, losses):
        fer = self.fer(self.student_cfg, params)
        base = self._load_or_none("baseline", self.student_cfg)
        base_fer = self.fer(self.student_cfg, base)
        return {"n_steps": len(losses),
                "loss_first": losses[0] if losses else None,
                "loss_last": losses[-1] if losses else None,
                "val_fer": fer, "baseline_fer": base_fer,
                "rel_fer_reduction_pct":
                    round(100 * (base_fer - fer) / max(base_fer, 1e-9), 2)}

    def _smbr_strategy(self):
        """The paper's 16-GPU sMBR trainer: threshold-compressed SGD.
        ``gtc_workers > 1`` runs the worker axis through GTCShardMap on
        a mesh (the axis spans the devices when the worker count
        divides them, else one device vmap-carries all workers — the
        same math either way, pinned bitwise in tests)."""
        pc = self.pc
        if pc.gtc_workers <= 1:
            return GTC(GTCConfig(tau=pc.gtc_tau, n_workers=1), clip=0.0)
        # widest mesh the worker count divides onto: each device carries
        # workers/n_dev unrolled workers (all of them on 1 device at
        # laptop scale; one each on the paper's 16-GPU shape)
        mesh = worker_mesh(pc.gtc_workers)
        return GTCShardMap(
            GTCConfig(tau=pc.gtc_tau, n_workers=pc.gtc_workers),
            mesh, clip=0.0)

    def stage_smbr(self) -> Dict:
        """Sequence training of the SSL student on labeled data only,
        under threshold-compressed SGD — the paper's sMBR trainer
        (§3.4), multi-worker by default (``gtc_workers``): each update
        consumes one batch per worker and exchanges int8-packed sends
        over the worker axis."""
        pc = self.pc
        stage = f"student_{self.student_trainer}"
        params = self._load_or_none(stage, self.student_cfg)
        if params is None:
            params = self._load_or_none("baseline", self.student_cfg)
        model = build_model(self.student_cfg)
        sink = ListSink()
        tr = self._trainer(
            "smbr", self._smbr_strategy(),
            {"smbr": make_smbr_loss_fn(model, self.student_cfg,
                                       self._graph(),
                                       kappa=pc.smbr_kappa)}, sink)
        state = tr.init_state(params, seed=pc.seed)
        state = tr.fit(state, epoch_source(
            lambda ep: self._batches(self.rng_labeled, chunked=False,
                                     uniform_len=pc.gtc_workers > 1),
            pc.smbr_epochs, pc.smbr_lr, "smbr"))
        tr.finalize(state)
        self._ckpt("smbr").save(0, state.params)
        fer = self.fer(self.student_cfg, state.params)
        base = self._load_or_none("baseline", self.student_cfg)
        base_fer = self.fer(self.student_cfg, base)
        return {"eacc_first": sink.first("expected_frame_acc"),
                "eacc_last": sink.last("expected_frame_acc"),
                "val_fer": fer, "baseline_fer": base_fer,
                "rel_fer_reduction_pct":
                    round(100 * (base_fer - fer) / max(base_fer, 1e-9), 2)}

    # ----------------------------------------------------------------- run

    def run(self, stage: str = "all") -> Dict:
        if stage != "all":
            return getattr(self, f"stage_{stage}")()
        out = {}
        for s in ("baseline", "teacher", "targets", "student", "smbr"):
            out[s] = getattr(self, f"stage_{s}")()
            print(f"[pipeline] {s}: {out[s]}")
        return out

    # ---------------------------------------------------------------- waves

    def run_waves(self, n_waves: int = 2, *, kill_at: int = 1,
                  revive_after: int = 2) -> Dict:
        """Continuous elastic scheduled learning: generate -> train ->
        promote, repeated, surviving injected worker deaths.

        Wave 0 distills from the bidirectional teacher; every later
        wave *regenerates* the targets with the previous wave's student
        promoted to teacher (iterative distillation — "Exploiting
        Large-scale Teacher-Student Training", PAPERS.md) through the
        v2 store's atomic wave supersede.  Each wave's BMUF student fit
        runs under a :class:`~repro.runtime.workers.TrainerMembership`
        with a scripted :class:`~repro.runtime.workers.LaneCrashPlan`:
        one lane is killed after block ``kill_at`` (the block average
        shrinks to the survivors at the next sync) and revived
        ``revive_after`` blocks later (warm rejoin — lanes are kept
        broadcast-current exactly for this).  Requires the ``bmuf``
        student trainer (the only one with worker-stacked state to be
        elastic over).

        Returns per-wave generation + student reports plus the final
        health checks: manifest checksum-verified, superseded waves
        garbage-collected, generation ledger fully done.
        """
        from repro.pipeline.generate import WorkLedger
        from repro.runtime.workers import LaneCrashPlan, TrainerMembership

        pc = self.pc
        assert self.student_trainer == "bmuf", \
            "elastic waves need the BMUF student trainer"
        assert pc.bmuf_workers >= 2, "need >= 2 lanes to kill one"
        assert self._load_or_none("baseline", self.student_cfg) \
            is not None, "run stage baseline first"

        membership = TrainerMembership(
            os.path.join(self.out, "trainer_members.json"),
            timeout_s=30.0)
        lanes = [f"lane{i}" for i in range(pc.bmuf_workers)]

        waves = []
        prev_stage = None       # None -> the bilstm teacher generates
        for w in range(n_waves):
            gen = self.stage_targets(promoted_stage=prev_stage)
            # every lane rejoins at the wave boundary (revived workers
            # come back warm; the roster is the ground truth mid-wave)
            for lane in lanes:
                membership.join(lane)
            victim = lanes[-1 - (w % (len(lanes) - 1))]  # rotate, keep lane0
            plan = LaneCrashPlan(
                membership,
                kills={} if kill_at is None else {kill_at: victim},
                revives={} if kill_at is None or revive_after is None
                else {kill_at + revive_after: victim})
            stage = f"student_wave{w}"
            init = (None if prev_stage is None
                    else self._load_or_none(prev_stage, self.student_cfg))
            rep = self.stage_student(membership=plan, init_params=init,
                                     stage=stage)
            rep["chaos"] = plan.log
            waves.append({"wave": gen["wave"], "gen": gen, "student": rep})
            print(f"[waves] wave {w}: gen wave={gen['wave']} "
                  f"fer={rep['val_fer']:.3f} resizes={rep['resizes']} "
                  f"chaos={plan.log}")
            prev_stage = stage  # student promoted to teacher

        store = LogitStoreV2(os.path.join(self.out, "logit_store"),
                             k=pc.topk, vocab=pc.n_senones)
        n_verified = store.verify()
        removed = store.gc()    # superseded waves leave no orphans
        ledger_clean = WorkLedger.peek_all_done(
            os.path.join(self.out, "gen_ledger.json"))
        return {"n_waves": n_waves, "waves": waves,
                "manifest_clean": True, "n_verified": n_verified,
                "gc_removed": len(removed), "ledger_clean": ledger_clean,
                "restarts_absorbed": sum(
                    1 for wv in waves
                    for e in wv["student"].get("chaos", [])
                    if e.get("event") == "kill"),
                "resize_count": sum(
                    wv["student"]["resizes"]["count"] for wv in waves),
                "resize_seconds": round(sum(
                    wv["student"]["resizes"]["seconds"] for wv in waves),
                    3),
                "final_fer": waves[-1]["student"]["val_fer"],
                "rel_fer_reduction_pct":
                    waves[-1]["student"]["rel_fer_reduction_pct"]}
