from repro.core import distill, logit_store, scheduled, teacher

__all__ = ["distill", "logit_store", "scheduled", "teacher"]
