import importlib

from repro.core import distill, logit_store, scheduled

__all__ = ["distill", "logit_store", "scheduled", "teacher"]


def __getattr__(name):
    # lazy: teacher pulls in repro.serve (whose decode path imports
    # launch.steps -> repro.core) — eager import here would be a cycle.
    # import_module (not `from ... import`) avoids __getattr__ recursion.
    if name == "teacher":
        return importlib.import_module("repro.core.teacher")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
