"""Student/teacher losses (paper §3.2) with vocab-chunked streaming math.

The paper's objective: CE between the teacher's senone posterior and the
student's posterior, with the teacher distribution reconstructed from the
stored top-k logits (missing entries = large negative  =>  renormalized
top-k softmax).  Generalized here to any softmax output (senones or token
vocabs up to 262k).

No loss here materializes the full (tokens x vocab) logit matrix: logsumexp
and the label/top-k gathers stream over vocab chunks of the unembedding
matrix.  ``repro.kernels.sparse_ce`` is the Pallas twin of the fused
gather+logsumexp inner loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_FILL = -1e9          # paper: "filling the missing logits with large
                         # negative values"


# ------------------------------------------------------------- full-logit
# reference implementations (small vocab / tests)

def soft_ce(student_logits, teacher_logits, temperature: float = 1.0):
    """CE(teacher || student), mean over frames."""
    t = jax.nn.log_softmax(teacher_logits / temperature, axis=-1)
    s = jax.nn.log_softmax(student_logits / temperature, axis=-1)
    return -jnp.mean(jnp.sum(jnp.exp(t) * s, axis=-1))


def topk_soft_ce(student_logits, topk_vals, topk_idx):
    """CE against the reconstructed top-k teacher distribution."""
    # reconstruct: scatter top-k values into a NEG_FILL canvas
    canvas = jnp.full(student_logits.shape, NEG_FILL, jnp.float32)
    canvas = jax.vmap(lambda c, i, v: c.at[i].set(v.astype(jnp.float32)))(
        canvas.reshape(-1, canvas.shape[-1]),
        topk_idx.reshape(-1, topk_idx.shape[-1]),
        topk_vals.reshape(-1, topk_vals.shape[-1]))
    canvas = canvas.reshape(student_logits.shape)
    return soft_ce(student_logits, canvas)


# ------------------------------------------------------------ chunked CE

def _chunked_logsumexp_and_gather(h, w_unembed, gather_idx, *, chunk: int,
                                  softcap: float = 0.0):
    """Stream over vocab chunks of w_unembed (D, V).

    h: (T, D) hidden states; gather_idx: (T, K) vocab ids to gather logits
    for.  Returns (logsumexp (T,), gathered (T, K)) in float32 without ever
    materializing (T, V).
    """
    t, d = h.shape
    v = w_unembed.shape[1]
    k = gather_idx.shape[-1]
    nchunks = (v + chunk - 1) // chunk
    vpad = nchunks * chunk
    wpad = jnp.pad(w_unembed, ((0, 0), (0, vpad - v)))
    hf = h

    def body(carry, ci):
        m, l, g = carry
        wc = jax.lax.dynamic_slice_in_dim(wpad, ci * chunk, chunk, axis=1)
        logits = (hf @ wc.astype(hf.dtype)).astype(jnp.float32)
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        # mask padded vocab tail
        vid = ci * chunk + jnp.arange(chunk)
        logits = jnp.where(vid[None, :] < v, logits, -jnp.inf)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.exp(logits - m_new[:, None]).sum(-1)
        # gather any requested ids that live in this chunk
        loc = gather_idx - ci * chunk
        inside = (loc >= 0) & (loc < chunk)
        picked = jnp.take_along_axis(logits, jnp.clip(loc, 0, chunk - 1),
                                     axis=-1)
        g = jnp.where(inside, picked, g)
        return (m_new, l, g), None

    m0 = jnp.full((t,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((t,), jnp.float32)
    g0 = jnp.full((t, k), NEG_FILL, jnp.float32)
    (m, l, g), _ = jax.lax.scan(body, (m0, l0, g0), jnp.arange(nchunks))
    return m + jnp.log(jnp.maximum(l, 1e-30)), g


def chunked_ce(h, w_unembed, labels, *, chunk: int = 8192,
               softcap: float = 0.0, mask=None):
    """Hard-label CE from hidden states, vocab-chunked. h (B,S,D)."""
    b, s, d = h.shape
    hf = h.reshape(b * s, d)
    lab = labels.reshape(b * s, 1)
    lse, gathered = _chunked_logsumexp_and_gather(hf, w_unembed, lab,
                                                  chunk=chunk,
                                                  softcap=softcap)
    nll = lse - gathered[:, 0]
    if mask is not None:
        mk = mask.reshape(b * s).astype(jnp.float32)
        return jnp.sum(nll * mk) / jnp.maximum(mk.sum(), 1.0)
    return jnp.mean(nll)


def chunked_topk_distill_ce(h, w_unembed, topk_vals, topk_idx, *,
                            chunk: int = 8192, softcap: float = 0.0,
                            mask=None, use_kernel: bool = False,
                            interpret=None):
    """Paper §3.2.2 loss: CE between the renormalized top-k teacher
    distribution and the student's full-vocab distribution.

    teacher q_i = softmax over the k stored logits (missing = NEG_FILL,
    i.e. effectively zero mass).  loss = Σ_i q_i (lse_student - z_i).

    ``use_kernel=True`` routes the logsumexp+gather inner loop through
    ``kernels.sparse_ce`` (Pallas; differentiable via its custom_vjp —
    the streamed XLA scan below stays the default and the oracle).
    ``interpret`` follows the kernels/_dispatch convention.
    """
    b, s, d = h.shape
    k = topk_idx.shape[-1]
    hf = h.reshape(b * s, d)
    idx = topk_idx.reshape(b * s, k)
    vals = topk_vals.reshape(b * s, k).astype(jnp.float32)
    if use_kernel:
        from repro.kernels.sparse_ce import topk_distill_ce
        return topk_distill_ce(
            hf, w_unembed, vals, idx, softcap=softcap, interpret=interpret,
            mask=None if mask is None else mask.reshape(b * s))
    lse, z = _chunked_logsumexp_and_gather(hf, w_unembed, idx, chunk=chunk,
                                           softcap=softcap)
    q = jax.nn.softmax(vals, axis=-1)                    # teacher top-k mass
    nll = jnp.sum(q * (lse[:, None] - z), axis=-1)
    if mask is not None:
        mk = mask.reshape(b * s).astype(jnp.float32)
        return jnp.sum(nll * mk) / jnp.maximum(mk.sum(), 1.0)
    return jnp.mean(nll)


def frame_accuracy(student_logits, labels):
    return jnp.mean((jnp.argmax(student_logits, -1) == labels)
                    .astype(jnp.float32))
