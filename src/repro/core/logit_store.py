"""Top-k logit store (paper §3.2.2).

"To reduce bandwidth and storage requirements as we parallelize across
multiple GPUs, we store only the k highest valued logits. ... We found
storing the top-20 values for k to be sufficient."

The store is a sharded on-disk archive of (values bf16, indices int32)
pairs per frame, written by the teacher target-generation pass and read by
the student trainer.  ``topk_compress`` / ``reconstruct`` are the in-memory
codecs; ``repro.kernels.topk_logits`` is the Pallas TPU kernel for the
selection hot loop.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distill import NEG_FILL


def topk_compress(logits, k: int):
    """logits (..., V) -> (vals (..., k) bf16, idx (..., k) int32).

    Values are stored *shifted* so that the max logit is 0 — softmax is
    shift-invariant and bf16 precision concentrates near 0 (storage trick:
    keeps 8-bit-exponent error negligible for the dominant mass).
    """
    vals, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    vals = vals - vals[..., :1]
    return vals.astype(jnp.bfloat16), idx.astype(jnp.int32)


def reconstruct(vals, idx, vocab: int):
    """Lossy reconstruction: missing logits filled with NEG_FILL."""
    shape = vals.shape[:-1] + (vocab,)
    canvas = jnp.full((int(np.prod(shape[:-1])), vocab), NEG_FILL,
                      jnp.float32)
    flat_v = vals.reshape(-1, vals.shape[-1]).astype(jnp.float32)
    flat_i = idx.reshape(-1, idx.shape[-1])
    canvas = jax.vmap(lambda c, i, v: c.at[i].set(v))(canvas, flat_i, flat_v)
    return canvas.reshape(shape)


def storage_bytes_per_frame(k: int) -> int:
    return k * (2 + 4)          # bf16 value + int32 index


def full_bytes_per_frame(vocab: int) -> int:
    return vocab * 4


@dataclass
class ShardMeta:
    n_frames: int
    k: int
    vocab: int


class LogitStore:
    """Directory of npz shards: one shard per (worker, sub-epoch chunk).

    Layout: <root>/shard_<i>.npz {vals, idx, utt_lens} + meta.json.
    Writes happen from the teacher inference pass (parallelized over
    workers — the paper's 'parallelize target generation'); reads stream
    shards in worker-local order for the student trainer.
    """

    def __init__(self, root: str, *, k: int = 20, vocab: int = 0):
        self.root = root
        self.k = k
        self.vocab = vocab
        os.makedirs(root, exist_ok=True)

    def write_shard(self, shard_id: int, vals, idx, utt_lens=None):
        vals = np.asarray(jax.device_get(vals), dtype=np.float32)
        idx = np.asarray(jax.device_get(idx), dtype=np.int32)
        path = os.path.join(self.root, f"shard_{shard_id:05d}.npz")
        np.savez_compressed(
            path, vals=vals.astype(np.float16), idx=idx,
            utt_lens=np.asarray(utt_lens if utt_lens is not None else
                                [vals.shape[0]], np.int32))
        meta = {"k": self.k, "vocab": self.vocab}
        with open(os.path.join(self.root, "meta.json"), "w") as f:
            json.dump(meta, f)
        return path

    def read_shard(self, shard_id: int):
        path = os.path.join(self.root, f"shard_{shard_id:05d}.npz")
        z = np.load(path)
        return (jnp.asarray(z["vals"], jnp.bfloat16),
                jnp.asarray(z["idx"], jnp.int32))

    def shards(self):
        return sorted(f for f in os.listdir(self.root)
                      if f.startswith("shard_"))

    def stats(self):
        n = 0
        for s in self.shards():
            z = np.load(os.path.join(self.root, s))
            n += int(np.prod(z["idx"].shape[:-1]))
        return ShardMeta(n_frames=n, k=self.k, vocab=self.vocab)
