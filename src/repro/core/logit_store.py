"""Top-k logit store (paper §3.2.2).

"To reduce bandwidth and storage requirements as we parallelize across
multiple GPUs, we store only the k highest valued logits. ... We found
storing the top-20 values for k to be sufficient."

The store is a sharded on-disk archive of (values bf16, indices int32)
pairs per frame, written by the teacher target-generation pass and read by
the student trainer.  ``topk_compress`` / ``reconstruct`` are the in-memory
codecs; ``repro.kernels.topk_logits`` is the Pallas TPU kernel for the
selection hot loop.

This module keeps the codecs, the storage math, and the **v1** store
(one compressed npz per shard).  The production archive is
``repro.store.LogitStoreV2`` — manifest-backed, memory-mapped,
wave-versioned — which reads v1 archives in place via its migration
path; new producers should write through ``repro.pipeline.generate``.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distill import NEG_FILL


def topk_compress(logits, k: int):
    """logits (..., V) -> (vals (..., k) bf16, idx (..., k) int32).

    Values are stored *shifted* so that the max logit is 0 — softmax is
    shift-invariant and bf16 precision concentrates near 0 (storage trick:
    keeps 8-bit-exponent error negligible for the dominant mass).
    """
    vals, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    vals = vals - vals[..., :1]
    return vals.astype(jnp.bfloat16), idx.astype(jnp.int32)


def reconstruct(vals, idx, vocab: int, *, row_chunk: int = 0):
    """Lossy reconstruction: missing logits filled with NEG_FILL.

    With ``row_chunk`` > 0 the scatter streams over blocks of
    ``row_chunk`` frames (``lax.map``), so the working set beyond the
    output itself is bounded by one (row_chunk, vocab) block — the
    unchunked path's vmapped functional scatter peaks at ~2x the full
    (frames, vocab) canvas, which at a 262k token vocab is the
    difference between fitting and OOM.  Loss paths should not call
    this at all: ``distill.chunked_topk_distill_ce`` (and the
    ``kernels/sparse_ce`` gather) consume top-k directly without ever
    materializing the canvas.
    """
    k = vals.shape[-1]
    shape = vals.shape[:-1] + (vocab,)
    n = int(np.prod(shape[:-1]))
    flat_v = vals.reshape(n, k).astype(jnp.float32)
    flat_i = idx.reshape(n, k)

    def scatter_rows(v, i):
        c = jnp.full((v.shape[0], vocab), NEG_FILL, jnp.float32)
        return jax.vmap(lambda c_, i_, v_: c_.at[i_].set(v_))(c, i, v)

    if row_chunk and n > row_chunk:
        pad = (-n) % row_chunk
        pv = jnp.pad(flat_v, ((0, pad), (0, 0)))
        pi = jnp.pad(flat_i, ((0, pad), (0, 0)))
        blocks = jax.lax.map(
            lambda args: scatter_rows(*args),
            (pv.reshape(-1, row_chunk, k), pi.reshape(-1, row_chunk, k)))
        canvas = blocks.reshape(-1, vocab)[:n]
    else:
        canvas = scatter_rows(flat_v, flat_i)
    return canvas.reshape(shape)


def iter_reconstruct(vals, idx, vocab: int, row_chunk: int = 1024):
    """Host-side streaming reconstruction: yields (lo, hi, block) over
    row blocks without ever allocating the full canvas — for consumers
    (eval dumps, calibration sweeps) that scan frames once."""
    k = vals.shape[-1]
    flat_v = np.asarray(vals, np.float32).reshape(-1, k)
    flat_i = np.asarray(idx).reshape(-1, k)
    n = flat_v.shape[0]
    for lo in range(0, n, row_chunk):
        hi = min(lo + row_chunk, n)
        block = np.full((hi - lo, vocab), NEG_FILL, np.float32)
        np.put_along_axis(block, flat_i[lo:hi], flat_v[lo:hi], axis=-1)
        yield lo, hi, block


def storage_bytes_per_frame(k: int) -> int:
    return k * (2 + 4)          # bf16 value + int32 index


def full_bytes_per_frame(vocab: int) -> int:
    return vocab * 4


@dataclass
class ShardMeta:
    n_frames: int
    k: int
    vocab: int


class LogitStore:
    """Directory of npz shards: one shard per (worker, sub-epoch chunk).

    Layout: <root>/shard_<i>.npz {vals, idx, utt_lens} + meta.json.
    Writes happen from the teacher inference pass (parallelized over
    workers — the paper's 'parallelize target generation'); reads stream
    shards in worker-local order for the student trainer.
    """

    def __init__(self, root: str, *, k: int = 20, vocab: int = 0):
        self.root = root
        self.k = k
        self.vocab = vocab
        os.makedirs(root, exist_ok=True)

    def append_shard(self, shard_id: int, vals, idx, utt_lens=None, *,
                     wave: int = 0):
        """v2-API spelling so the pipeline layer is store-agnostic; v1
        has no wave generations — the tag is accepted and dropped."""
        del wave
        return self.write_shard(shard_id, vals, idx, utt_lens)

    def write_shard(self, shard_id: int, vals, idx, utt_lens=None):
        vals = np.asarray(jax.device_get(vals), dtype=np.float32)
        idx = np.asarray(jax.device_get(idx), dtype=np.int32)
        path = os.path.join(self.root, f"shard_{shard_id:05d}.npz")
        np.savez_compressed(
            path, vals=vals.astype(np.float16), idx=idx,
            utt_lens=np.asarray(utt_lens if utt_lens is not None else
                                [vals.shape[0]], np.int32))
        meta = {"k": self.k, "vocab": self.vocab}
        with open(os.path.join(self.root, "meta.json"), "w") as f:
            json.dump(meta, f)
        return path

    def read_shard(self, shard_id: int):
        path = os.path.join(self.root, f"shard_{shard_id:05d}.npz")
        z = np.load(path)
        return (jnp.asarray(z["vals"], jnp.bfloat16),
                jnp.asarray(z["idx"], jnp.int32))

    def shards(self):
        return sorted(f for f in os.listdir(self.root)
                      if f.startswith("shard_"))

    def stats(self):
        n = 0
        for s in self.shards():
            z = np.load(os.path.join(self.root, s))
            n += int(np.prod(z["idx"].shape[:-1]))
        return ShardMeta(n_frames=n, k=self.k, vocab=self.vocab)
