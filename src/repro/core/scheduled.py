"""Scheduled learning (paper §3.3): interleave unlabeled sub-epochs with
labeled passes, exponential LR decay over sub-epochs, chunked-BPTT for early
sub-epochs then full-sequence fine-tuning, rotating feature offsets on
labeled passes.

Paper schedules:
  100k hours: 4 sub-epochs x 25k hrs; labeled pass after EVERY sub-epoch;
              chunked BPTT for sub-epochs 1-3, full-sequence on the 4th.
  1M hours:   18 sub-epochs x ~55k hrs; labeled pass after every 5th;
              chunked for sub-epochs 1-15, fine-tune (full seq) on 16-18.
The generator below emits phase descriptors that a trainer consumes; sizes
are configurable so laptop-scale runs keep the exact *structure*.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List


@dataclass(frozen=True)
class Phase:
    kind: str                 # "unlabeled" | "labeled"
    sub_epoch: int            # 1-based index over unlabeled sub-epochs
    lr: float
    chunked: bool             # chunked BPTT (32-frame) vs full-sequence
    feature_offset: int       # 0/1/2 rotation on labeled passes (paper §2)
    hours: float


@dataclass
class ScheduleConfig:
    n_sub_epochs: int = 18
    sub_epoch_hours: float = 55_000.0
    labeled_hours: float = 7_000.0
    labeled_every: int = 5            # labeled pass after every N sub-epochs
    chunked_until: int = 15           # sub-epochs > this run full-sequence
    lr0: float = 5e-4
    lr_decay: float = 0.85            # exponential decay per sub-epoch
    labeled_lr_boost: float = 1.5     # "slightly higher learning rates on
                                      #  the labeled data"
    n_feature_offsets: int = 3

    @classmethod
    def paper_100k(cls, **kw) -> "ScheduleConfig":
        return cls(n_sub_epochs=4, sub_epoch_hours=25_000.0,
                   labeled_every=1, chunked_until=3, **kw)

    @classmethod
    def paper_1m(cls, **kw) -> "ScheduleConfig":
        return cls(n_sub_epochs=18, sub_epoch_hours=55_000.0,
                   labeled_every=5, chunked_until=15, **kw)


def schedule(cfg: ScheduleConfig) -> Iterator[Phase]:
    """Yield the interleaved phase sequence."""
    offset = 0
    for se in range(1, cfg.n_sub_epochs + 1):
        lr = cfg.lr0 * (cfg.lr_decay ** (se - 1))
        chunked = se <= cfg.chunked_until
        yield Phase("unlabeled", se, lr, chunked, -1, cfg.sub_epoch_hours)
        if se % cfg.labeled_every == 0 or se == cfg.n_sub_epochs:
            yield Phase("labeled", se, lr * cfg.labeled_lr_boost, chunked,
                        offset, cfg.labeled_hours)
            offset = (offset + 1) % cfg.n_feature_offsets


def phases(cfg: ScheduleConfig) -> List[Phase]:
    return list(schedule(cfg))


def describe(cfg: ScheduleConfig) -> str:
    out = []
    for p in phases(cfg):
        out.append(f"sub-epoch {p.sub_epoch:2d} {p.kind:9s} "
                   f"lr={p.lr:.2e} {'chunked' if p.chunked else 'full-seq'}"
                   + (f" offset={p.feature_offset}" if p.kind == "labeled"
                      else ""))
    return "\n".join(out)
