"""The store half of the million-hour data plane (paper §3.2.2).

"To reduce bandwidth and storage requirements as we parallelize across
multiple GPUs, we store only the k highest valued logits."  The storage
math that makes a million hours tractable lives in
``repro.core.logit_store.storage_bytes_per_frame``: one frame costs
``k * (2 + 4)`` bytes (bf16 value + int32 index) instead of
``vocab * 4`` — k=20 against the paper's 3,183 senones is a ~26x
reduction, and it is what lets target generation "scale out"
embarrassingly in parallel while the archive stays on disk rather than
in a database.

This package is LogitStore **v2**: a manifest-backed sharded archive
(JSON manifest carrying per-shard frame counts, k, vocab, wave tag and
checksum; memory-mapped shard reads; append/retire semantics so a
regenerated teacher wave supersedes stale shards atomically) replacing
the v1 one-npz-per-shard layout, plus a migration reader that serves v1
archives through the same API.  The codecs (``topk_compress`` /
``reconstruct``) stay in ``repro.core.logit_store``; producers write
through ``repro.pipeline.generate`` and consumers read through
``repro.train.data.distill_shard_source``.
"""
from repro.store.logit_store import LogitStoreV2, migrate_v1
from repro.store.manifest import (Manifest, ShardCorruptionError,
                                  ShardEntry, StaleWaveError, StoreError,
                                  file_checksum)


def __getattr__(name):
    # lazy: the byte-math helpers live in the jax-importing v1 module,
    # and multi-process generation workers (repro.runtime.workers)
    # import this package on a spawn-time budget — they must stay
    # numpy-only unless the engine itself wants jax
    if name in ("storage_bytes_per_frame", "full_bytes_per_frame"):
        from repro.core import logit_store as _v1
        return getattr(_v1, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "LogitStoreV2", "migrate_v1",
    "Manifest", "ShardEntry", "file_checksum",
    "StoreError", "ShardCorruptionError", "StaleWaveError",
    "storage_bytes_per_frame", "full_bytes_per_frame",
]
