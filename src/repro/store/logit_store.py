"""LogitStore v2: manifest-backed sharded top-k archive (paper §3.2.2).

Layout under <root>:

    manifest.json                        — the index (repro.store.manifest)
    shards/shard_<id>_w<wave>.vals.npy   — (N..., k) float16, max-shifted
    shards/shard_<id>_w<wave>.idx.npy    — (N..., k) int32 vocab ids
    shards/shard_<id>_w<wave>.lens.npy   — (U,) int32 per-utterance lengths

Raw ``.npy`` (not the v1 compressed ``.npz``) so reads memory-map:
``read_shard`` costs an mmap + page faults for the touched frames, not a
full decompress — the student trainer streams a sub-epoch's shards
without ever holding more than its working set.

Write protocol (``append_shard``): data files land first under
wave-tagged names, the checksummed manifest entry commits via atomic
rename, and the superseded entry moves to the manifest's **retired**
list with its files left on disk.  The supersede is atomic **per
shard**: a reader sees each shard's old complete wave or its new
complete wave, never torn bytes, and a writer killed before the
manifest commit leaves that shard's previous wave live.  Retired files
are finally deleted by ``gc()`` — invoked on store open (also sweeping
any staged-but-never-committed files a killed writer leaked) — which is
what lets a consumer *pin* a wave for a whole sub-epoch
(``train.data.distill_shard_source(pin_wave=True)`` snapshots the live
entries and reads them via ``read_entry`` even while a regeneration
supersedes them concurrently).  The gc-on-open contract assumes the
single-writer-at-a-time discipline ``pipeline.generate``'s ledger
provides: never open a store for writing while another writer is
mid-stage.

Cross-shard consistency is the producer's job — a regeneration killed
mid-wave durably leaves earlier shards at the new wave and later ones
at the old, and ``pipeline.generate``'s resumable work ledger is what
closes that window: the next invocation re-claims the unfinished
ranges and completes the wave.

v1 stores (``shard_*.npz`` + ``meta.json``) migrate via ``migrate_v1``:
existing archives are indexed in place (format tag "v1-npz", checksum
computed at migration), readable through the same API, and superseded
shard-by-shard as a new wave rewrites them in v2 format.
"""
from __future__ import annotations

import os
import re
from typing import Iterable, List, Optional, Tuple

import numpy as np

from repro.runtime.procs import file_lock
from repro.store.manifest import (Manifest, ShardCorruptionError,
                                  ShardEntry, StoreError, file_checksum)

_SHARD_DIR = "shards"
_V1_SHARD_RE = re.compile(r"shard_(\d+)\.npz$")


class LogitStoreV2:
    """Manifest-backed sharded archive of (vals f16, idx i32) per frame."""

    def __init__(self, root: str, *, k: int = 0, vocab: int = 0,
                 gc_on_open: bool = True, shared: bool = False):
        """``shared=True`` is the multi-process-writer mode: every
        manifest commit becomes a locked reload-merge-save (N worker
        processes with disjoint shard ids then interleave commits
        without losing each other's entries), and gc-on-open is forced
        off — a worker must never sweep a sibling's staged files.  The
        supervisor (single process, before the workers exist) opens the
        store unshared and does the gc."""
        self.root = root
        self.shared = shared
        if shared:
            gc_on_open = False
        os.makedirs(os.path.join(root, _SHARD_DIR), exist_ok=True)
        if Manifest.exists(root):
            self.manifest = Manifest.load(root)
            # a caller's k/vocab must agree with what is on disk; 0 means
            # "whatever the store says" (read-only consumers)
            if k and self.manifest.k and k != self.manifest.k:
                raise StoreError(f"store has k={self.manifest.k}, "
                                 f"caller wants k={k}")
            if vocab and self.manifest.vocab and vocab != self.manifest.vocab:
                raise StoreError(f"store has vocab={self.manifest.vocab}, "
                                 f"caller wants vocab={vocab}")
        elif _find_v1_shards(root):
            self.manifest = _index_v1(root, k=k, vocab=vocab)
            self.manifest.save(root)
        else:
            self.manifest = Manifest(k=k, vocab=vocab)
        self.k = self.manifest.k or k
        self.vocab = self.manifest.vocab or vocab
        if gc_on_open:
            # sweep retired waves + orphans a killed writer left behind.
            # gc_on_open=False is for readers deliberately racing a
            # live writer (they must not delete its staged files).
            self.gc()

    # -------------------------------------------------------------- write

    def _shard_files(self, shard_id: int, wave: int) -> dict:
        stem = os.path.join(_SHARD_DIR, f"shard_{shard_id:05d}_w{wave:04d}")
        return {"vals": stem + ".vals.npy", "idx": stem + ".idx.npy",
                "lens": stem + ".lens.npy"}

    def _write_shard_files(self, shard_id: int, vals, idx, utt_lens=None,
                           *, wave: int = 0) -> ShardEntry:
        """Stage a shard's data files on disk WITHOUT committing them to
        the manifest — split out so the commit is a separate, atomic
        step (and so tests can simulate a writer killed in between)."""
        vals = np.asarray(vals, dtype=np.float32).astype(np.float16)
        idx = np.asarray(idx, dtype=np.int32)
        if vals.shape != idx.shape:
            raise ValueError(f"vals {vals.shape} != idx {idx.shape}")
        lens = np.asarray(utt_lens if utt_lens is not None
                          else [int(np.prod(vals.shape[:-1]))], np.int32)
        files = self._shard_files(shard_id, wave)
        np.save(os.path.join(self.root, files["vals"]), vals)
        np.save(os.path.join(self.root, files["idx"]), idx)
        np.save(os.path.join(self.root, files["lens"]), lens)
        return ShardEntry(
            shard_id=shard_id, wave=wave,
            n_frames=int(np.prod(idx.shape[:-1])),
            k=int(idx.shape[-1]), vocab=self.vocab, files=files,
            checksum=file_checksum(files, self.root), format="v2")

    @property
    def _manifest_lock(self) -> str:
        return os.path.join(self.root, "manifest.lock")

    def _commit(self, entry: ShardEntry):
        """Manifest swap; the superseded entry is *retired* (files kept
        on disk for wave-pinned readers) and reclaimed by ``gc()``.

        Shared mode serializes the read-modify-write: under the
        manifest lock, the on-disk manifest (which siblings may have
        advanced) is reloaded, this entry superseded into *that*, and
        the result saved — so concurrent writers with disjoint shard
        ids compose instead of clobbering."""
        if not self.shared:
            self.manifest.supersede(entry)
            self.manifest.save(self.root)
            return
        with file_lock(self._manifest_lock):
            if Manifest.exists(self.root):
                self.manifest = Manifest.load(self.root)
                self.manifest.k = self.manifest.k or self.k
                self.manifest.vocab = self.manifest.vocab or self.vocab
            self.manifest.supersede(entry)
            self.manifest.save(self.root)

    def append_shard(self, shard_id: int, vals, idx, utt_lens=None, *,
                     wave: int = 0) -> str:
        """Write one shard and commit it; returns the vals file path.

        With ``wave`` above the live entry's, the new shard atomically
        supersedes it (stale files retired after the manifest commit);
        an older wave raises StaleWaveError.
        """
        entry = self._write_shard_files(shard_id, vals, idx, utt_lens,
                                        wave=wave)
        self._commit(entry)
        return os.path.join(self.root, entry.files["vals"])

    # legacy spelling used by v1 call sites (wave 0 append)
    def write_shard(self, shard_id: int, vals, idx, utt_lens=None):
        return self.append_shard(shard_id, vals, idx, utt_lens)

    # --------------------------------------------------------------- read

    def read_shard(self, shard_id: int, *, verify: bool = False
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """-> (vals (..., k) float16, idx (..., k) int32).

        v2 shards come back memory-mapped (zero-copy until touched);
        v1-npz entries decompress (the migration reader).  ``verify``
        recomputes the checksum first — it reads every byte, so it is
        the consumer's opt-in integrity gate, not the default.
        """
        return self.read_entry(self.manifest.entry(shard_id),
                               verify=verify)

    def read_entry(self, entry: ShardEntry, *, verify: bool = False
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Read a shard through an explicit (possibly pinned) entry.

        This is the wave-pinning read path: a consumer snapshots the
        live entries at sub-epoch start and keeps reading *those* even
        if a concurrent regeneration supersedes them — retired files
        stay on disk until ``gc()``, so the pinned pass stays
        wave-consistent instead of silently mixing teachers mid-epoch.
        """
        if verify:
            self.verify_entry(entry)
        if entry.format == "v1-npz":
            z = np.load(os.path.join(self.root, entry.files["npz"]))
            return z["vals"].astype(np.float16), z["idx"].astype(np.int32)
        vals = np.load(os.path.join(self.root, entry.files["vals"]),
                       mmap_mode="r")
        idx = np.load(os.path.join(self.root, entry.files["idx"]),
                      mmap_mode="r")
        return vals, idx

    def read_lens(self, shard_id: int) -> np.ndarray:
        entry = self.manifest.entry(shard_id)
        if entry.format == "v1-npz":
            z = np.load(os.path.join(self.root, entry.files["npz"]))
            return z["utt_lens"].astype(np.int32)
        return np.load(os.path.join(self.root, entry.files["lens"]))

    # ---------------------------------------------------------- integrity

    def verify_shard(self, shard_id: int):
        self.verify_entry(self.manifest.entry(shard_id))

    def verify_entry(self, entry: ShardEntry):
        try:
            got = file_checksum(entry.files, self.root)
        except FileNotFoundError as e:
            raise ShardCorruptionError(
                f"shard {entry.shard_id} (wave {entry.wave}): data file "
                f"missing ({e}) — a pinned entry read after gc()?") from e
        if got != entry.checksum:
            raise ShardCorruptionError(
                f"shard {entry.shard_id} (wave {entry.wave}): checksum "
                f"{got[:12]}... != manifest {entry.checksum[:12]}...")

    def verify(self) -> int:
        """Checksum every live shard; returns the count verified."""
        for sid in self.manifest.shard_ids():
            self.verify_shard(sid)
        return len(self.manifest.shards)

    # ----------------------------------------------- garbage collection

    def gc(self) -> List[str]:
        """Reclaim dead shard files; returns the relpaths removed.

        Two populations die here (and only here — commits never delete):

        * files of **retired** entries — waves superseded while a
          pinned reader may still have been on them; by open time that
          reader is gone, so the previous wave's files go, and the
          manifest's retired list is cleared;
        * **orphans** in ``shards/`` referenced by no live or retired
          entry — staged by a writer that died between ``np.save`` and
          the manifest commit, which would otherwise leak forever (a
          resumed pass rewrites the same wave-tagged names, but an
          abandoned one never would).

        Runs on store open (``gc_on_open``).  Contract: no *other*
        writer is mid-stage on this root — the generation ledger's
        single-pass-at-a-time discipline.
        """
        live = {rel for e in self.manifest.shards.values()
                for rel in e.files.values()}
        removed = []

        def _rm(rel: str):
            path = os.path.join(self.root, rel)
            if os.path.exists(path):
                os.remove(path)
                removed.append(rel)

        # retired entries first: their files may live outside shards/
        # (v1-npz archives sit at the store root)
        for entry in self.manifest.retired:
            for rel in entry.files.values():
                if rel not in live:
                    _rm(rel)
        sdir = os.path.join(self.root, _SHARD_DIR)
        for fname in sorted(os.listdir(sdir)):
            rel = os.path.join(_SHARD_DIR, fname)
            if rel not in live:
                _rm(rel)
        if self.manifest.retired:
            self.manifest.retired = []
            self.manifest.save(self.root)
        return removed

    # ------------------------------------------------------------ queries

    def shards(self) -> List[int]:
        return self.manifest.shard_ids()

    def next_wave(self) -> int:
        return self.manifest.max_wave() + 1

    def stats(self) -> "ShardMeta":
        """O(manifest) — v1 walked and decompressed every shard."""
        # deferred import: ShardMeta lives in the jax-importing v1
        # module, and the multi-process generation workers (which never
        # call stats) must stay numpy-only for fast spawn
        from repro.core.logit_store import ShardMeta
        return ShardMeta(n_frames=self.manifest.n_frames(),
                         k=self.k, vocab=self.vocab)


# ------------------------------------------------------------ v1 migration

def _find_v1_shards(root: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(root):
        return []
    out = []
    for f in os.listdir(root):
        m = _V1_SHARD_RE.match(f)
        if m:
            out.append((int(m.group(1)), f))
    return sorted(out)


def _index_v1(root: str, *, k: int = 0, vocab: int = 0) -> Manifest:
    """Build a v2 manifest over an existing v1 archive, in place.

    The npz files are not rewritten — each becomes a "v1-npz" entry with
    a checksum computed now; subsequent waves supersede them with v2
    files shard-by-shard.
    """
    meta_path = os.path.join(root, "meta.json")
    if os.path.exists(meta_path):
        import json
        with open(meta_path) as f:
            meta = json.load(f)
        k = k or int(meta.get("k", 0))
        vocab = vocab or int(meta.get("vocab", 0))
    manifest = Manifest(k=k, vocab=vocab)
    for sid, fname in _find_v1_shards(root):
        z = np.load(os.path.join(root, fname))
        files = {"npz": fname}
        manifest.shards[sid] = ShardEntry(
            shard_id=sid, wave=0,
            n_frames=int(np.prod(z["idx"].shape[:-1])),
            k=int(z["idx"].shape[-1]), vocab=vocab, files=files,
            checksum=file_checksum(files, root), format="v1-npz")
    return manifest


def migrate_v1(root: str, *, k: int = 0, vocab: int = 0) -> LogitStoreV2:
    """Open a v1 archive as a v2 store (indexes shards, writes the
    manifest).  Idempotent: an already-migrated root just loads."""
    return LogitStoreV2(root, k=k, vocab=vocab)
