"""LogitStore v2: manifest-backed sharded top-k archive (paper §3.2.2).

Layout under <root>:

    manifest.json                        — the index (repro.store.manifest)
    shards/shard_<id>_w<wave>.vals.npy   — (N..., k) float16, max-shifted
    shards/shard_<id>_w<wave>.idx.npy    — (N..., k) int32 vocab ids
    shards/shard_<id>_w<wave>.lens.npy   — (U,) int32 per-utterance lengths

Raw ``.npy`` (not the v1 compressed ``.npz``) so reads memory-map:
``read_shard`` costs an mmap + page faults for the touched frames, not a
full decompress — the student trainer streams a sub-epoch's shards
without ever holding more than its working set.

Write protocol (``append_shard``): data files land first under
wave-tagged names, the checksummed manifest entry commits via atomic
rename, and only then are the superseded wave's files deleted.  The
supersede is atomic **per shard**: a reader sees each shard's old
complete wave or its new complete wave, never torn bytes, and a writer
killed before the manifest commit leaves that shard's previous wave
live.  Cross-shard consistency is the producer's job — a regeneration
killed mid-wave durably leaves earlier shards at the new wave and later
ones at the old, and ``pipeline.generate``'s resumable work ledger is
what closes that window: the next invocation re-claims the unfinished
ranges and completes the wave.  (A consumer that must pin one wave for
a whole pass can check ``manifest`` wave tags; see ROADMAP.)

v1 stores (``shard_*.npz`` + ``meta.json``) migrate via ``migrate_v1``:
existing archives are indexed in place (format tag "v1-npz", checksum
computed at migration), readable through the same API, and superseded
shard-by-shard as a new wave rewrites them in v2 format.
"""
from __future__ import annotations

import os
import re
from typing import Iterable, List, Optional, Tuple

import numpy as np

from repro.core.logit_store import ShardMeta
from repro.store.manifest import (Manifest, ShardCorruptionError,
                                  ShardEntry, StoreError, file_checksum)

_SHARD_DIR = "shards"
_V1_SHARD_RE = re.compile(r"shard_(\d+)\.npz$")


class LogitStoreV2:
    """Manifest-backed sharded archive of (vals f16, idx i32) per frame."""

    def __init__(self, root: str, *, k: int = 0, vocab: int = 0):
        self.root = root
        os.makedirs(os.path.join(root, _SHARD_DIR), exist_ok=True)
        if Manifest.exists(root):
            self.manifest = Manifest.load(root)
            # a caller's k/vocab must agree with what is on disk; 0 means
            # "whatever the store says" (read-only consumers)
            if k and self.manifest.k and k != self.manifest.k:
                raise StoreError(f"store has k={self.manifest.k}, "
                                 f"caller wants k={k}")
            if vocab and self.manifest.vocab and vocab != self.manifest.vocab:
                raise StoreError(f"store has vocab={self.manifest.vocab}, "
                                 f"caller wants vocab={vocab}")
        elif _find_v1_shards(root):
            self.manifest = _index_v1(root, k=k, vocab=vocab)
            self.manifest.save(root)
        else:
            self.manifest = Manifest(k=k, vocab=vocab)
        self.k = self.manifest.k or k
        self.vocab = self.manifest.vocab or vocab

    # -------------------------------------------------------------- write

    def _shard_files(self, shard_id: int, wave: int) -> dict:
        stem = os.path.join(_SHARD_DIR, f"shard_{shard_id:05d}_w{wave:04d}")
        return {"vals": stem + ".vals.npy", "idx": stem + ".idx.npy",
                "lens": stem + ".lens.npy"}

    def _write_shard_files(self, shard_id: int, vals, idx, utt_lens=None,
                           *, wave: int = 0) -> ShardEntry:
        """Stage a shard's data files on disk WITHOUT committing them to
        the manifest — split out so the commit is a separate, atomic
        step (and so tests can simulate a writer killed in between)."""
        vals = np.asarray(vals, dtype=np.float32).astype(np.float16)
        idx = np.asarray(idx, dtype=np.int32)
        if vals.shape != idx.shape:
            raise ValueError(f"vals {vals.shape} != idx {idx.shape}")
        lens = np.asarray(utt_lens if utt_lens is not None
                          else [int(np.prod(vals.shape[:-1]))], np.int32)
        files = self._shard_files(shard_id, wave)
        np.save(os.path.join(self.root, files["vals"]), vals)
        np.save(os.path.join(self.root, files["idx"]), idx)
        np.save(os.path.join(self.root, files["lens"]), lens)
        return ShardEntry(
            shard_id=shard_id, wave=wave,
            n_frames=int(np.prod(idx.shape[:-1])),
            k=int(idx.shape[-1]), vocab=self.vocab, files=files,
            checksum=file_checksum(files, self.root), format="v2")

    def _commit(self, entry: ShardEntry):
        """Manifest swap + retirement of the superseded files."""
        old = self.manifest.supersede(entry)
        self.manifest.save(self.root)
        if old is not None:
            for rel in old.files.values():
                path = os.path.join(self.root, rel)
                if os.path.exists(path):
                    os.remove(path)

    def append_shard(self, shard_id: int, vals, idx, utt_lens=None, *,
                     wave: int = 0) -> str:
        """Write one shard and commit it; returns the vals file path.

        With ``wave`` above the live entry's, the new shard atomically
        supersedes it (stale files retired after the manifest commit);
        an older wave raises StaleWaveError.
        """
        entry = self._write_shard_files(shard_id, vals, idx, utt_lens,
                                        wave=wave)
        self._commit(entry)
        return os.path.join(self.root, entry.files["vals"])

    # legacy spelling used by v1 call sites (wave 0 append)
    def write_shard(self, shard_id: int, vals, idx, utt_lens=None):
        return self.append_shard(shard_id, vals, idx, utt_lens)

    # --------------------------------------------------------------- read

    def read_shard(self, shard_id: int, *, verify: bool = False
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """-> (vals (..., k) float16, idx (..., k) int32).

        v2 shards come back memory-mapped (zero-copy until touched);
        v1-npz entries decompress (the migration reader).  ``verify``
        recomputes the checksum first — it reads every byte, so it is
        the consumer's opt-in integrity gate, not the default.
        """
        entry = self.manifest.entry(shard_id)
        if verify:
            self.verify_shard(shard_id)
        if entry.format == "v1-npz":
            z = np.load(os.path.join(self.root, entry.files["npz"]))
            return z["vals"].astype(np.float16), z["idx"].astype(np.int32)
        vals = np.load(os.path.join(self.root, entry.files["vals"]),
                       mmap_mode="r")
        idx = np.load(os.path.join(self.root, entry.files["idx"]),
                      mmap_mode="r")
        return vals, idx

    def read_lens(self, shard_id: int) -> np.ndarray:
        entry = self.manifest.entry(shard_id)
        if entry.format == "v1-npz":
            z = np.load(os.path.join(self.root, entry.files["npz"]))
            return z["utt_lens"].astype(np.int32)
        return np.load(os.path.join(self.root, entry.files["lens"]))

    # ---------------------------------------------------------- integrity

    def verify_shard(self, shard_id: int):
        entry = self.manifest.entry(shard_id)
        got = file_checksum(entry.files, self.root)
        if got != entry.checksum:
            raise ShardCorruptionError(
                f"shard {shard_id} (wave {entry.wave}): checksum "
                f"{got[:12]}... != manifest {entry.checksum[:12]}...")

    def verify(self) -> int:
        """Checksum every live shard; returns the count verified."""
        for sid in self.manifest.shard_ids():
            self.verify_shard(sid)
        return len(self.manifest.shards)

    # ------------------------------------------------------------ queries

    def shards(self) -> List[int]:
        return self.manifest.shard_ids()

    def next_wave(self) -> int:
        return self.manifest.max_wave() + 1

    def stats(self) -> ShardMeta:
        """O(manifest) — v1 walked and decompressed every shard."""
        return ShardMeta(n_frames=self.manifest.n_frames(),
                         k=self.k, vocab=self.vocab)


# ------------------------------------------------------------ v1 migration

def _find_v1_shards(root: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(root):
        return []
    out = []
    for f in os.listdir(root):
        m = _V1_SHARD_RE.match(f)
        if m:
            out.append((int(m.group(1)), f))
    return sorted(out)


def _index_v1(root: str, *, k: int = 0, vocab: int = 0) -> Manifest:
    """Build a v2 manifest over an existing v1 archive, in place.

    The npz files are not rewritten — each becomes a "v1-npz" entry with
    a checksum computed now; subsequent waves supersede them with v2
    files shard-by-shard.
    """
    meta_path = os.path.join(root, "meta.json")
    if os.path.exists(meta_path):
        import json
        with open(meta_path) as f:
            meta = json.load(f)
        k = k or int(meta.get("k", 0))
        vocab = vocab or int(meta.get("vocab", 0))
    manifest = Manifest(k=k, vocab=vocab)
    for sid, fname in _find_v1_shards(root):
        z = np.load(os.path.join(root, fname))
        files = {"npz": fname}
        manifest.shards[sid] = ShardEntry(
            shard_id=sid, wave=0,
            n_frames=int(np.prod(z["idx"].shape[:-1])),
            k=int(z["idx"].shape[-1]), vocab=vocab, files=files,
            checksum=file_checksum(files, root), format="v1-npz")
    return manifest


def migrate_v1(root: str, *, k: int = 0, vocab: int = 0) -> LogitStoreV2:
    """Open a v1 archive as a v2 store (indexes shards, writes the
    manifest).  Idempotent: an already-migrated root just loads."""
    return LogitStoreV2(root, k=k, vocab=vocab)
