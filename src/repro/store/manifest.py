"""Manifest: the LogitStore v2 index — one JSON file naming every live shard.

The manifest is the store's single source of truth: a shard exists iff
the manifest names it.  Shard data files are written first (to
wave-tagged names that never collide with the live entries), then the
manifest is swapped atomically (`os.replace`) — so a reader holding the
old manifest always sees intact files, and a writer killed at any point
leaves each shard's old or new entry fully live, never torn bytes
(cross-shard wave consistency is the producer's ledger's job — see
repro.pipeline.generate).

Superseded entries are *retired*, not deleted: they move to the
manifest's ``retired`` list with their files left on disk, so a reader
that pinned a wave at sub-epoch start (``train.data
.distill_shard_source(pin_wave=True)``) keeps reading consistent
targets while a new teacher wave lands.  ``LogitStoreV2.gc()`` —
invoked on store open — is what finally deletes retired files, along
with any staged-but-never-committed files a killed writer left behind.

Each entry records the shard's frame count, k, vocab, wave (teacher
generation tag — higher wave supersedes), on-disk file names, storage
format ("v2" raw .npy triple, memory-mappable; "v1-npz" the legacy
compressed archive, readable in place by the migration path), and a
sha256 checksum over the data files.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from dataclasses import dataclass, field
from typing import Dict, Optional

MANIFEST_VERSION = 2


class StoreError(RuntimeError):
    """Base class for store integrity failures."""


class ShardCorruptionError(StoreError):
    """A shard's bytes no longer match its manifest checksum."""


class StaleWaveError(StoreError):
    """A writer tried to commit a shard older than the live one."""


@dataclass
class ShardEntry:
    shard_id: int
    wave: int
    n_frames: int
    k: int
    vocab: int
    files: Dict[str, str]            # role ("vals"/"idx"/"lens") -> relpath
    checksum: str                    # sha256 hex over the data files
    format: str = "v2"               # "v2" | "v1-npz"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "ShardEntry":
        return cls(**d)


def file_checksum(paths, root: str) -> str:
    """sha256 over the named files' bytes, in sorted role order.

    Role names are mixed into the digest so swapping two same-sized
    files (vals <-> idx) cannot produce a colliding checksum.
    """
    h = hashlib.sha256()
    for role in sorted(paths):
        h.update(role.encode())
        with open(os.path.join(root, paths[role]), "rb") as f:
            for block in iter(lambda: f.read(1 << 20), b""):
                h.update(block)
    return h.hexdigest()


@dataclass
class Manifest:
    """In-memory manifest + atomic on-disk round-trip."""

    k: int = 0
    vocab: int = 0
    shards: Dict[int, ShardEntry] = field(default_factory=dict)
    retired: list = field(default_factory=list)   # superseded ShardEntry,
    version: int = MANIFEST_VERSION               # files pending gc()

    FILENAME = "manifest.json"

    # ------------------------------------------------------------------ io

    @classmethod
    def path_for(cls, root: str) -> str:
        return os.path.join(root, cls.FILENAME)

    @classmethod
    def exists(cls, root: str) -> bool:
        return os.path.exists(cls.path_for(root))

    @classmethod
    def load(cls, root: str) -> "Manifest":
        with open(cls.path_for(root)) as f:
            d = json.load(f)
        if d.get("version") != MANIFEST_VERSION:
            raise StoreError(f"manifest version {d.get('version')!r} "
                             f"!= {MANIFEST_VERSION}")
        shards = {int(sid): ShardEntry.from_json(e)
                  for sid, e in d.get("shards", {}).items()}
        retired = [ShardEntry.from_json(e) for e in d.get("retired", [])]
        return cls(k=d["k"], vocab=d["vocab"], shards=shards,
                   retired=retired)

    def save(self, root: str):
        """Atomic commit: full write to a temp file, then os.replace.

        A reader never observes a half-written manifest, and a writer
        killed before the replace leaves the previous manifest live.
        """
        payload = {"version": self.version, "k": self.k,
                   "vocab": self.vocab,
                   "shards": {str(sid): e.to_json()
                              for sid, e in sorted(self.shards.items())},
                   "retired": [e.to_json() for e in self.retired]}
        tmp = self.path_for(root) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path_for(root))

    # ------------------------------------------------------------- queries

    def entry(self, shard_id: int) -> ShardEntry:
        if shard_id not in self.shards:
            raise KeyError(f"shard {shard_id} not in manifest")
        return self.shards[shard_id]

    def shard_ids(self):
        return sorted(self.shards)

    def n_frames(self) -> int:
        return sum(e.n_frames for e in self.shards.values())

    def max_wave(self) -> int:
        return max((e.wave for e in self.shards.values()), default=-1)

    # -------------------------------------------------------------- update

    def supersede(self, entry: ShardEntry) -> Optional[ShardEntry]:
        """Install `entry`, moving the predecessor (if any) onto the
        ``retired`` list — its files stay on disk for readers that
        pinned the old wave, until ``LogitStoreV2.gc()``.

        Same-wave rewrites are allowed (shard contents are deterministic,
        so an idempotent retry rewrites in place); an *older* wave is a
        stale writer and is rejected.
        """
        old = self.shards.get(entry.shard_id)
        if old is not None and entry.wave < old.wave:
            raise StaleWaveError(
                f"shard {entry.shard_id}: wave {entry.wave} < live "
                f"wave {old.wave}")
        self.shards[entry.shard_id] = entry
        if old is not None and old.files == entry.files:
            return None                     # in-place rewrite: nothing retired
        if old is not None:
            self.retired.append(old)
        return old
