"""Streaming-AM sessions on the slot-based serving core.

  PYTHONPATH=src python examples/serve_streams.py
  PYTHONPATH=src python examples/serve_streams.py --arch whisper-medium

Demonstrates the three things ``serve.StreamServer`` adds over the
lockstep ``StreamingEngine.feed`` loop:

  * SLO tiers — firehose streams (offline target generation) saturate
    every slot; interactive streams arriving later are admitted first,
    parking firehose mid-flight;
  * mid-flight detach/reattach — a detached stream's recurrent-state
    row is pulled to the host, its slot serves other work, and a later
    ``reattach`` restores it bitwise (emissions identical to an
    uninterrupted run);
  * live streams — ``submit(..., final=False)`` + ``append``/``close``
    for audio that arrives while the session is already attached.

Works for any streaming-capable arch: the causal LSTM AM emits top-k
senone posteriors per *frame*; whisper emits one incremental-decoder
position per *chunk* (chunk-local encoder, growing cross-attention).
"""
import argparse

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.configs.base import Segment
from repro.configs.lstm_am_7khr import CONFIG
from repro.models import build_model
from repro.models.api import stream_feat_dim, stream_frame_sync
from repro.serve import SLO_DEFAULT, StreamServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lstm-am",
                    help="'lstm-am' or any streaming-capable arch name "
                         "(e.g. whisper-medium)")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--chunk", type=int, default=16)
    args = ap.parse_args()

    if args.arch == "lstm-am":
        cfg = CONFIG.replace(
            lstm_hidden=32, feat_dim=16, n_senones=49, vocab_size=49,
            segments=(Segment((CONFIG.segments[0].pattern[0],),
                              repeat=2),))
    else:
        cfg = reduced(get_arch(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    fd = stream_feat_dim(cfg)
    rng = np.random.default_rng(0)

    srv = StreamServer(cfg, params, n_slots=args.slots,
                       chunk_frames=args.chunk, k=5, tiers=SLO_DEFAULT)

    # --- tiers: firehose saturates the server, interactive preempts
    # whisper's cross-attn buffers cap audio per stream (max_frames);
    # the LSTM AM's O(1) state has no cap — size the demo accordingly
    n_chunks = 40 if stream_frame_sync(cfg) else 256 // args.chunk - 8
    fire = [(rng.normal(size=(n_chunks * args.chunk, fd)) * 0.1)
            .astype(np.float32) for _ in range(args.slots)]
    rf = [srv.submit(u, tier="firehose") for u in fire]
    done = srv.pump()
    inter = (rng.normal(size=(args.chunk, fd)) * 0.1).astype(np.float32)
    ri = srv.submit(inter, tier="interactive")
    while ri not in done:
        done.update(srv.pump())
    print(f"interactive stream {ri} finished at sync "
          f"{done[ri].finished_sync} ({srv.stats['parked']} firehose "
          f"parked for it); occupancy now {srv.occupancy()}")

    # --- detach / reattach: pull a live stream's state row to the host
    live = [r for r in rf if r not in done]
    if live:
        rid = live[0]
        srv.detach(rid)
        print(f"stream {rid} detached mid-flight (state row held on "
              f"host); server keeps pumping without it")
        done.update(srv.pump())        # the freed slot keeps serving
        srv.reattach(rid)
    done.update(srv.drain())

    # --- live stream: audio arrives after the session is attached
    head = (rng.normal(size=(args.chunk, fd)) * 0.1).astype(np.float32)
    tail = (rng.normal(size=(args.chunk, fd)) * 0.1).astype(np.float32)
    rl = srv.submit(head, final=False)
    srv.pump()                         # consumes head, then idles
    srv.append(rl, tail)
    srv.close(rl)
    done.update(srv.drain())

    for rid in sorted(done):
        v, i = done[rid].emissions()
        print(f"stream {rid:>2} ({done[rid].tier or 'default':<11}): "
              f"{v.shape[0]:>3} emissions x top-{v.shape[1]}")
    st = srv.stats
    print(f"{st['syncs']} host syncs / {st['steps']} window steps, "
          f"{st['parked']} parks, frame utilization "
          f"{srv.utilization():.0%}")


if __name__ == "__main__":
    main()
