"""Quickstart: the paper's SSL loop in ~80 lines of public API.

  PYTHONPATH=src python examples/quickstart.py

1. Synthesize a labeled + unlabeled far-field corpus (deterministic).
2. Train a baseline LSTM AM on the labeled split (CE).
3. Train a bidirectional teacher; generate top-k=10 logits for the
   unlabeled split into a LogitStore (no decoder, no confidence model).
4. Train the student with the distillation loss on unlabeled data.
"""
from repro.core.ssl_pipeline import PipelineConfig, SSLPipeline


def main():
    pc = PipelineConfig(n_labeled=24, n_unlabeled=48, n_val=8,
                        epochs_baseline=2, n_sub_epochs=2,
                        labeled_every=1, chunked_until=2)
    pipe = SSLPipeline(pc, out_dir="experiments/quickstart")

    print("== 1. baseline supervised AM (paper §2) ==")
    base = pipe.stage_baseline()
    print(f"   val FER {base['val_fer']:.3f}")

    print("== 2. bidirectional teacher + sMBR (paper §3.2) ==")
    teach = pipe.stage_teacher()
    print(f"   val FER {teach['val_fer']:.3f}")

    print("== 3. top-k target generation (paper §3.2.2) ==")
    targ = pipe.stage_targets()
    print(f"   {targ['n_frames']} frames, "
          f"{targ['storage_compression_x']}x storage compression")

    print("== 4. scheduled student training (paper §3.3) ==")
    stud = pipe.stage_student()
    print(f"   val FER {stud['val_fer']:.3f} "
          f"({stud['rel_fer_reduction_pct']}% rel. reduction vs baseline)")


if __name__ == "__main__":
    main()
