"""GTC vs BMUF on the same workload (paper §3.5 / Tables 1-2).

  PYTHONPATH=src python examples/distributed_trainers.py

Trains the same reduced AM with (a) GTC: gradient-threshold-compressed
synchronous SGD (the 16-GPU trainer) and (b) BMUF: blockwise model-update
filtering with Nesterov block momentum (the 64-GPU trainer), printing the
loss curves and the GTC wire density — the trade the paper's §5.2
quantifies as "in attempting to scale to 64 GPUs, we lose some of the
gains".

Both runs are the *same* Trainer.fit() loop over the same data source;
only the DistributedStrategy constructor argument differs — the point
of the unified Trainer API.

Topology flags (repro.runtime):
  REPRO_HOST_DEVICES=8 python examples/distributed_trainers.py
      — run the shard_map trainers on a real 8-device host mesh
  python examples/distributed_trainers.py --cluster host:port,N,i
      — multi-host launch via jax.distributed (single-process specs
        are a no-op)
"""
from repro.runtime.env import bootstrap_from_env
bootstrap_from_env()    # before the first jax import (locks XLA flags)

import argparse

import jax

from repro.core.ssl_pipeline import PipelineConfig, SSLPipeline
from repro.distributed.bmuf import BMUFConfig
from repro.distributed.gtc import GTCConfig
from repro.launch.steps import make_loss_fn
from repro.models import build_model
from repro.runtime.cluster import ClusterConfig, initialize, worker_mesh
from repro.train import (GTC, BMUFVmap, GTCShardMap, ListSink, Trainer,
                         epoch_source)


def run(strategy, label, *, model, cfg, batches, epochs=3, lr=5e-2):
    sink = ListSink()
    trainer = Trainer(strategy, {"ce": make_loss_fn(model, cfg, "ce")},
                      metrics=sink)
    state = trainer.init_state(model.init(jax.random.key(0)))
    state = trainer.fit(state, epoch_source(lambda ep: batches, epochs,
                                            lr, "ce"))
    print(f"  {label}: {int(state.step)} updates, "
          f"loss {sink.first('loss'):.3f} -> {sink.last('loss'):.3f}")
    return state, sink


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", default="",
                    help="'env' or 'host:port,N,i' (see runtime.cluster)")
    args = ap.parse_args()
    if args.cluster:
        info = initialize(ClusterConfig.from_spec(args.cluster))
        print(f"cluster: process {info.process_index}/{info.process_count}")

    pc = PipelineConfig(n_labeled=32, n_val=8, epochs_baseline=1)
    pipe = SSLPipeline(pc, out_dir="experiments/trainers")
    cfg = pipe.student_cfg
    model = build_model(cfg)
    batches = pipe._batches(pipe.rng_labeled, chunked=True, seed=0)
    print(f"{len(batches)} chunked batches of {pc.batch}x{pc.chunk_len}")

    print("\n== GTC (threshold compression, error feedback) ==")
    _, sink = run(GTC(GTCConfig(tau=5e-4, n_workers=1)), "gtc",
                  model=model, cfg=cfg, batches=batches)
    dens = sink.last("gtc_density")
    print(f"  wire density {dens:.3f} "
          f"(bandwidth saving ~{1 / max(dens, 1e-3):.0f}x)")

    mesh = worker_mesh(2)     # widest device mesh 2 workers divide onto
    print(f"\n== GTCShardMap (2 workers, int8 wire over a "
          f"{mesh.devices.size}-device mesh) ==")
    run(GTCShardMap(GTCConfig(tau=5e-4, n_workers=2), mesh),
        "gtc_shardmap", model=model, cfg=cfg, batches=batches)

    bc = BMUFConfig(n_workers=4, block_steps=2)
    print(f"\n== BMUF ({bc.n_workers} workers, block sync every "
          f"{bc.block_steps} steps) ==")
    run(BMUFVmap(bc), "bmuf", model=model, cfg=cfg, batches=batches)

    print("\nGTC communicates every step (a compressed int8 psum — "
          "GTCShardMap is the worker-axis-sharded form); BMUF every "
          f"{bc.block_steps} steps (full model mean + block momentum).")


if __name__ == "__main__":
    main()
