"""GTC vs BMUF on the same workload (paper §3.5 / Tables 1-2).

  PYTHONPATH=src python examples/distributed_trainers.py

Trains the same reduced AM with (a) GTC: gradient-threshold-compressed
synchronous SGD (the 16-GPU trainer) and (b) BMUF: blockwise model-update
filtering with Nesterov block momentum (the 64-GPU trainer), printing the
loss curves and the GTC wire density — the trade the paper's §5.2
quantifies as "in attempting to scale to 64 GPUs, we lose some of the
gains".
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ssl_pipeline import PipelineConfig, SSLPipeline
from repro.distributed import bmuf as bmuf_lib
from repro.distributed import gtc as gtc_lib
from repro.launch.steps import init_opt_state, make_loss_fn, make_train_step
from repro.models import build_model
from repro.optim import momentum_update


def main():
    pc = PipelineConfig(n_labeled=32, n_val=8, epochs_baseline=1)
    pipe = SSLPipeline(pc, out_dir="experiments/trainers")
    cfg = pipe.student_cfg
    model = build_model(cfg)
    batches = pipe._batches(pipe.rng_labeled, chunked=True, seed=0)
    print(f"{len(batches)} chunked batches of {pc.batch}x{pc.chunk_len}")

    # ---- GTC: compressed synchronous SGD ----
    print("\n== GTC (threshold compression, error feedback) ==")
    params = model.init(jax.random.key(0))
    loss_fn = make_loss_fn(model, cfg, "ce")
    gc = gtc_lib.GTCConfig(tau=5e-4, n_workers=1)
    gtc_state = gtc_lib.gtc_init(params)
    opt = init_opt_state(params)

    def gtc_step(params, opt, gtc_state, batch):
        (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        send, res = gtc_lib.compress_tree(g, gtc_state["residual"], gc.tau)
        params, opt = momentum_update(params, send, opt, lr=5e-2)
        m["density"] = gtc_lib.density(send, gc.tau)
        return params, opt, {"residual": res}, m

    step = jax.jit(gtc_step)
    for ep in range(3):
        for b in batches:
            bj = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, gtc_state, m = step(params, opt, gtc_state, bj)
        print(f"  epoch {ep}: loss {float(m['loss']):.3f} "
              f"wire density {float(m['density']):.3f} "
              f"(bandwidth saving ~{1/max(float(m['density']),1e-3):.0f}x)")

    # ---- BMUF: local steps + block sync ----
    print("\n== BMUF (4 workers, block sync every 2 steps) ==")
    bc = bmuf_lib.BMUFConfig(n_workers=4, block_steps=2)
    train_step = make_train_step(model, cfg, loss_kind="ce", lr=5e-2)
    block = jax.jit(bmuf_lib.make_bmuf_block_step(train_step, bc))
    params_b = model.init(jax.random.key(0))
    state = bmuf_lib.bmuf_init(params_b, bc)
    opt1 = init_opt_state(params_b)
    opts = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (4,) + x.shape).copy(), opt1)
    need = bc.block_steps * bc.n_workers
    group = []
    losses = []
    for ep in range(3):
        for b in batches:
            group.append({k: jnp.asarray(v) for k, v in b.items()})
            if len(group) == need:
                stacked = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs).reshape(
                        bc.block_steps, bc.n_workers, *xs[0].shape), *group)
                state, opts, ms = block(state, opts, stacked)
                losses.append(float(jnp.mean(ms["loss"])))
                group = []
        print(f"  epoch {ep}: mean block loss {losses[-1]:.3f} "
              f"(communication 1/{bc.block_steps} of sync SGD)")

    print("\nGTC communicates every step (compressed); BMUF every "
          f"{bc.block_steps} steps (full model mean + block momentum).")


if __name__ == "__main__":
    main()
