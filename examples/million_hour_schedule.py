"""The 1M-hour scheduled-learning recipe, end to end (paper §3.3/§6).

  PYTHONPATH=src python examples/million_hour_schedule.py

Prints the paper's exact 18-sub-epoch schedule (55k hours each, labeled
interleave every 5, chunked BPTT until 15, fine-tune 16-18), then executes
the same *structure* scaled to minutes of synthetic audio with the BMUF
trainer (the paper's 64-GPU arm), reporting per-sub-epoch relative FER
reduction — the laptop twin of the paper's Figure 1.

Data plane: target generation is partitioned across two ledgered
workers into the manifest-backed LogitStore v2, and every Trainer.fit
consumes its shards through the async prefetching feed — the same
producer/consumer path a real million-hour run scales out on
(repro.store + repro.pipeline).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduled
from repro.core.ssl_pipeline import PipelineConfig, SSLPipeline
from repro.models import build_model
from repro.seqtrain.smbr import frame_error_rate


def main():
    print("== the paper's 1M-hour schedule (structure) ==")
    print(scheduled.describe(scheduled.ScheduleConfig.paper_1m()))
    print()

    print("== scaled execution with BMUF (paper's 64-GPU arm) ==")
    pc = PipelineConfig(n_labeled=24, n_unlabeled=96, n_val=8,
                        epochs_baseline=2, n_sub_epochs=4,
                        labeled_every=2, chunked_until=3,
                        bmuf_workers=4, bmuf_block_steps=2,
                        gen_workers=2, prefetch=2)
    pipe = SSLPipeline(pc, out_dir="experiments/million_hour",
                       student_trainer="bmuf")
    base = pipe.stage_baseline()
    pipe.stage_teacher()
    targ = pipe.stage_targets()
    print(f"targets: {targ['n_shards']} manifest shards from "
          f"{targ['n_workers']} ledgered workers (wave {targ['wave']}), "
          f"{targ['storage_compression_x']}x storage compression")
    stud = pipe.stage_student()
    print(f"baseline FER {base['val_fer']:.3f} -> "
          f"BMUF student FER {stud['val_fer']:.3f} "
          f"({stud['rel_fer_reduction_pct']}% relative)")
    print("\n(sub-epoch loss trace is the scaled Fig. 1; see "
          "benchmarks/tables.py for the full reproduction)")


if __name__ == "__main__":
    main()
