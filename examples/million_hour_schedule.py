"""The 1M-hour scheduled-learning recipe, end to end (paper §3.3/§6).

  PYTHONPATH=src python examples/million_hour_schedule.py
  PYTHONPATH=src python examples/million_hour_schedule.py --elastic

Prints the paper's exact 18-sub-epoch schedule (55k hours each, labeled
interleave every 5, chunked BPTT until 15, fine-tune 16-18), then executes
the same *structure* scaled to minutes of synthetic audio with the BMUF
trainer (the paper's 64-GPU arm), reporting per-sub-epoch relative FER
reduction — the laptop twin of the paper's Figure 1.

``--elastic`` runs the continuous-wave driver instead: repeated
generate -> train -> promote waves (the student becomes the next wave's
teacher through the v2 store's atomic wave supersede) under injected
worker deaths — one BMUF lane is killed mid-wave and revived two blocks
later, the block average shrinking to the survivors and growing back at
the next sync.  The run ends with the store checksum-verified, orphans
garbage-collected, and the generation ledger fully done.

Data plane: target generation is partitioned across two ledgered
workers into the manifest-backed LogitStore v2, and every Trainer.fit
consumes its shards through the async prefetching feed — the same
producer/consumer path a real million-hour run scales out on
(repro.store + repro.pipeline).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduled
from repro.core.ssl_pipeline import PipelineConfig, SSLPipeline
from repro.models import build_model
from repro.seqtrain.smbr import frame_error_rate


def _pipeline(out_dir):
    # sized so each sub-epoch's unlabeled pass fills at least one full
    # BMUF block (workers*block_steps microbatches at one lr) — smaller
    # corpora drop every partial block at the phase boundary and the
    # student never updates
    pc = PipelineConfig(n_labeled=48, n_unlabeled=192, n_val=8,
                        epochs_baseline=2, n_sub_epochs=4,
                        labeled_every=2, chunked_until=3,
                        bmuf_workers=4, bmuf_block_steps=2,
                        gen_workers=2, prefetch=2)
    return SSLPipeline(pc, out_dir=out_dir, student_trainer="bmuf")


def run_static():
    print("== scaled execution with BMUF (paper's 64-GPU arm) ==")
    pipe = _pipeline("experiments/million_hour")
    base = pipe.stage_baseline()
    pipe.stage_teacher()
    targ = pipe.stage_targets()
    print(f"targets: {targ['n_shards']} manifest shards from "
          f"{targ['n_workers']} ledgered workers (wave {targ['wave']}), "
          f"{targ['storage_compression_x']}x storage compression")
    stud = pipe.stage_student()
    print(f"baseline FER {base['val_fer']:.3f} -> "
          f"BMUF student FER {stud['val_fer']:.3f} "
          f"({stud['rel_fer_reduction_pct']}% relative)")
    print("\n(sub-epoch loss trace is the scaled Fig. 1; see "
          "benchmarks/tables.py for the full reproduction)")


def run_elastic(n_waves):
    print(f"== elastic waves: generate -> train -> promote x{n_waves}, "
          f"one lane killed mid-wave ==")
    pipe = _pipeline("experiments/million_hour_elastic")
    base = pipe.stage_baseline()
    pipe.stage_teacher()
    rep = pipe.run_waves(n_waves, kill_at=1, revive_after=2)
    for i, wv in enumerate(rep["waves"]):
        s = wv["student"]
        chaos = ", ".join(f"{e['event']} {e['worker']}@block{e['poll']}"
                          for e in s["chaos"])
        print(f"wave {i}: store wave {wv['wave']}, "
              f"FER {s['val_fer']:.3f}, "
              f"{s['resizes']['count']} resizes "
              f"({s['resizes']['seconds']:.2f}s), "
              f"final W={s['final_workers']}  [{chaos}]")
    print(f"baseline FER {base['val_fer']:.3f} -> "
          f"final student FER {rep['final_fer']:.3f} "
          f"({rep['rel_fer_reduction_pct']}% relative)")
    print(f"absorbed {rep['restarts_absorbed']} worker deaths across "
          f"{rep['resize_count']} resizes "
          f"({rep['resize_seconds']:.2f}s total)")
    print(f"final manifest: verified {rep['n_verified']} shards "
          f"(clean={rep['manifest_clean']}), "
          f"gc removed {rep['gc_removed']} orphans, "
          f"ledger done={rep['ledger_clean']}")
    assert rep["manifest_clean"] and rep["ledger_clean"]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--elastic", action="store_true",
                    help="run the continuous-wave driver with injected "
                         "worker kills instead of the single-pass recipe")
    ap.add_argument("--waves", type=int, default=2,
                    help="number of generate->train->promote waves "
                         "(--elastic only)")
    args = ap.parse_args()

    print("== the paper's 1M-hour schedule (structure) ==")
    print(scheduled.describe(scheduled.ScheduleConfig.paper_1m()))
    print()

    if args.elastic:
        run_elastic(args.waves)
    else:
        run_static()


if __name__ == "__main__":
    main()
