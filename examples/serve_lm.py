"""Batched serving of an assigned LLM architecture (reduced config).

  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b

Routes through the unified inference engine (``repro.serve``): the
continuous batcher admits requests into per-row cache slots (ragged
prefill at each row's own position), retires rows on their own max_new,
and syncs emissions to the host once per decode window.  Works for
every assigned arch (attention KV ring-buffers for SWA, RG-LRU/xLSTM
recurrent states, MLA latent cache).
"""
import argparse
import time
from dataclasses import replace

import jax
import numpy as np

from repro.configs import ASSIGNED, get_arch, reduced
from repro.models import build_model
from repro.serve import LATENCY, THROUGHPUT, TokenServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b",
                    choices=ASSIGNED)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--policy", default="latency",
                    choices=["latency", "throughput"])
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    print(f"arch={args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model})")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    policy = (LATENCY if args.policy == "latency" else THROUGHPUT)
    policy = replace(policy, max_batch=args.batch)
    srv = TokenServer(cfg, params, policy=policy, max_seq=128)

    rng = np.random.default_rng(0)
    rids = [srv.submit(rng.integers(1, cfg.vocab_size, 5),
                       max_new=args.max_new) for _ in range(args.batch * 2)]
    t0 = time.time()
    done = srv.drain()
    dt = time.time() - t0
    tok = sum(len(done[r].out) for r in rids)
    st = srv.stats
    print(f"{len(rids)} requests, {tok} tokens in {dt:.1f}s "
          f"({tok/dt:.1f} tok/s on CPU, reduced config; {st['syncs']} "
          f"host syncs over {st['steps']} decode steps)")
    for r in rids[:3]:
        print(f"  req {r}: {done[r].out[:8]}...")


if __name__ == "__main__":
    main()
