"""Batched serving of an assigned LLM architecture (reduced config).

  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b

Exercises the decode path the decode_32k / long_500k dry-run shapes lower:
prefill a prompt, then batched single-token decode steps against the
KV/recurrent-state cache.  Works for every assigned arch (attention KV
ring-buffers for SWA, RG-LRU/xLSTM recurrent states, MLA latent cache).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED, get_arch, reduced
from repro.launch.serve import BatchedServer, Request
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b",
                    choices=ASSIGNED)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    print(f"arch={args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model})")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    srv = BatchedServer(cfg, params, batch_slots=args.batch, max_seq=128)

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(1, cfg.vocab_size, 5),
                    max_new=args.max_new) for i in range(args.batch * 2)]
    t0 = time.time()
    pending = list(reqs)
    while pending or any(s is not None for s in srv.slots):
        while pending and srv.submit(pending[0]):
            pending.pop(0)
        srv.step()
    dt = time.time() - t0
    tok = sum(len(r.out) for r in reqs)
    print(f"{len(reqs)} requests, {tok} tokens in {dt:.1f}s "
          f"({tok/dt:.1f} tok/s on CPU, reduced config)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
